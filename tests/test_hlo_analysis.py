"""Loop-aware HLO analyzer: exact flop/collective counts on known graphs."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((64, 32), jnp.float32)
    b = jnp.zeros((32, 16), jnp.float32)
    txt = _compile_text(lambda a, b: a @ b, a, b)
    st = analyze_hlo(txt)
    assert st.flops == 2 * 64 * 32 * 16


def test_scan_trip_count_multiplies():
    w = jnp.zeros((8, 16, 16), jnp.float32)
    x = jnp.zeros((4, 16), jnp.float32)

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    st = analyze_hlo(_compile_text(f, w, x))
    assert st.flops == 8 * (2 * 4 * 16 * 16), st.flops


def test_nested_scan_multiplies():
    w = jnp.zeros((3, 5, 8, 8), jnp.float32)
    x = jnp.zeros((2, 8), jnp.float32)

    def f(w, x):
        def outer(x, wo):
            def inner(x, wi):
                return x @ wi, None
            return jax.lax.scan(inner, x, wo)[0], None
        return jax.lax.scan(outer, x, w)[0]

    st = analyze_hlo(_compile_text(f, w, x))
    assert st.flops == 3 * 5 * (2 * 2 * 8 * 8), st.flops


def test_gradient_includes_backward_flops():
    w = jnp.zeros((16, 16), jnp.float32)
    x = jnp.zeros((4, 16), jnp.float32)

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    fwd = analyze_hlo(_compile_text(loss, w, x)).flops
    both = analyze_hlo(_compile_text(jax.grad(loss), w, x)).flops
    assert both >= 2 * fwd   # recomputed fwd matmul + d/dw matmul


def test_parse_computation_count():
    txt = _compile_text(lambda x: jnp.sum(jnp.tanh(x)), jnp.zeros((8, 8)))
    comps = parse_hlo(txt)
    assert "__entry__" in comps and len(comps) >= 2
